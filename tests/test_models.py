"""Per-architecture smoke tests: REDUCED variant of each assigned family runs
one forward/train step + prefill/decode on CPU with finite outputs and the
analytic param count matching the actual init."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import transformer

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.n_frontend_tokens:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_param_count(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = transformer.init(rng, cfg)
    n_actual = sum(x.size for x in jax.tree.leaves(params))
    assert n_actual == cfg.param_count()
    batch = _batch(cfg, rng)
    loss, aux = jax.jit(
        lambda p, b: transformer.forward(p, cfg, b, q_chunk=16))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch, rng):
    """One SGD step decreases nothing catastrophic: loss stays finite and
    grads are finite."""
    cfg = get_config(arch).reduced()
    params = transformer.init(rng, cfg)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        return transformer.forward(p, cfg, batch, q_chunk=16)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = jax.jit(loss_fn)(new_params)
    assert np.isfinite(float(loss2))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode_roundtrip(arch, rng):
    cfg = get_config(arch).reduced()
    params = transformer.init(rng, cfg)
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    logits, caches = jax.jit(
        lambda p, b: transformer.prefill(p, cfg, b, q_chunk=16))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    ids = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.int32(S + cfg.n_frontend_tokens)
    for _ in range(3):
        logits, caches = jax.jit(
            lambda p, i, c, t: transformer.decode_step(p, cfg, i, c, t))(
                params, ids, caches, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        ids = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


def test_decode_matches_prefill_continuation():
    """Greedy decode after prefill == forward over the extended sequence
    (consistency of the cache path), checked on a dense arch."""
    cfg = get_config("internlm2-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    B, S = 1, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch_s = {"tokens": toks[:, :S]}
    batch_s1 = {"tokens": toks}
    logits_p, caches = transformer.prefill(params, cfg, batch_s, q_chunk=16,
                                           cache_len=S + 4)
    logits_d, _ = transformer.decode_step(params, cfg, toks[:, S], caches,
                                          jnp.int32(S))
    logits_full, _ = transformer.prefill(params, cfg, batch_s1, q_chunk=17)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_sliding_window_decode_ring_buffer():
    """Ring-buffer SWA decode stays finite once position wraps the window."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              sliding_window=8)
    key = jax.random.PRNGKey(1)
    params = transformer.init(key, cfg)
    B = 2
    caches = transformer.init_caches(cfg, B, 1024, jnp.float32, window=8)
    ids = jnp.zeros((B,), jnp.int32)
    for t in range(20):   # wraps the 8-slot ring twice
        logits, caches = transformer.decode_step(params, cfg, ids, caches,
                                                 jnp.int32(t))
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        ids = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.slow
def test_int8_kv_cache_decode_close_to_bf16():
    """Perf-3 path: int8 KV cache decode stays within 1% of full precision
    and argmax-agrees over several steps."""
    cfg = get_config("qwen3-0.6b").reduced()
    key = jax.random.PRNGKey(7)
    params = transformer.init(key, cfg)
    B = 2
    c_f = transformer.init_caches(cfg, B, 64, jnp.float32)
    c_q = transformer.init_caches(cfg, B, 64, jnp.float32, kv_quant=True)
    idf = idq = jnp.zeros((B,), jnp.int32)
    for t in range(5):
        lf, c_f = transformer.decode_step(params, cfg, idf, c_f, jnp.int32(t))
        lq, c_q = transformer.decode_step(params, cfg, idq, c_q, jnp.int32(t))
        rel = (np.abs(np.asarray(lf) - np.asarray(lq)).max()
               / (np.abs(np.asarray(lf)).max() + 1e-9))
        assert rel < 0.02, rel
        assert np.array_equal(np.asarray(jnp.argmax(lf, -1)),
                              np.asarray(jnp.argmax(lq, -1)))
        idf = jnp.argmax(lf, -1).astype(jnp.int32)
        idq = jnp.argmax(lq, -1).astype(jnp.int32)


def test_moe_load_balance_aux():
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    key = jax.random.PRNGKey(2)
    params = transformer.init(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    loss, aux = transformer.forward(params, cfg, batch, q_chunk=16)
    # aux = E * sum(me*ce) >= 1 (perfectly balanced) per layer, summed over L
    assert float(aux) >= 0.9 * cfg.n_layers


# ---------------------------------------------------------------------------
# init determinism + forward-shape contracts (the engine relies on both:
# per-task init keys come from split/fold_in of one seed, and fusion
# stacks same-arch params along a leading task axis)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_init_deterministic_under_index_keys(arch, rng):
    """init must be a pure function of (key, cfg): the same fold_in-derived
    key reproduces params bitwise, a different index gives different params
    with the SAME tree structure (the stacking contract for task fusion)."""
    cfg = get_config(arch).reduced()
    k0, k1 = jax.random.fold_in(rng, 0), jax.random.fold_in(rng, 1)
    p_a = transformer.init(k0, cfg)
    p_b = transformer.init(k0, cfg)
    for la, lb in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    p_c = transformer.init(k1, cfg)
    assert jax.tree.structure(p_a) == jax.tree.structure(p_c)
    assert any(
        not np.array_equal(np.asarray(la), np.asarray(lc))
        for la, lc in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_c)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logits_shape_contract(arch, rng):
    """``transformer.logits`` covers exactly the token positions for every
    registry entry: [B, S, vocab_size], frontend positions sliced off."""
    cfg = get_config(arch).reduced()
    params = transformer.init(rng, cfg)
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    lg = jax.jit(
        lambda p, b: transformer.logits(p, cfg, b, q_chunk=16))(params, batch)
    assert lg.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
