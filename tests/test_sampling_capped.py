"""Property tests for the capped water-filling extension (footnote 3)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.core import sampling

settings.register_profile("ci2", max_examples=25, deadline=None)
settings.load_profile("ci2")


def _world(rng, V, S):
    U = np.abs(rng.normal(size=(V, S))) + 1e-3
    return U


@given(st.integers(3, 20), st.integers(1, 4), st.integers(0, 5000))
def test_capped_feasibility(V, S, seed):
    rng = np.random.default_rng(seed)
    U = _world(rng, V, S)
    eta = rng.uniform(0.2, 1.0, V)
    m = 0.4 * eta.sum()
    p = np.asarray(sampling.solve_waterfilling_capped(
        jnp.asarray(U), m, jnp.asarray(eta)))
    assert np.all(p >= -1e-9)
    assert np.all(p.sum(axis=1) <= eta + 1e-5)          # per-client caps
    np.testing.assert_allclose(p.sum(), m, rtol=1e-3)   # budget met


@given(st.integers(3, 12), st.integers(1, 3), st.integers(0, 5000))
def test_capped_reduces_to_uncapped(V, S, seed):
    """eta == 1 must reproduce the paper's Thm 8/9 solution exactly."""
    rng = np.random.default_rng(seed)
    U = _world(rng, V, S)
    m = 0.5 * V
    p_cap = np.asarray(sampling.solve_waterfilling_capped(
        jnp.asarray(U), m, jnp.ones(V)))
    p_ref = np.asarray(sampling.solve_waterfilling(jnp.asarray(U), m))
    np.testing.assert_allclose(p_cap, p_ref, atol=1e-5)


@given(st.integers(4, 12), st.integers(0, 2000))
def test_capped_optimality(V, seed):
    """KKT solution beats random feasible points on sum U^2/p."""
    rng = np.random.default_rng(seed)
    S = 2
    U = _world(rng, V, S)
    eta = rng.uniform(0.3, 1.0, V)
    m = 0.5 * eta.sum()
    p_star = np.asarray(sampling.solve_waterfilling_capped(
        jnp.asarray(U), m, jnp.asarray(eta)))

    def obj(p):
        return np.sum(np.where(U > 0, U ** 2 / np.maximum(p, 1e-30), 0.0))

    f_star = obj(p_star)
    for _ in range(25):
        q = rng.uniform(0.01, 1.0, (V, S))
        q = q / q.sum(axis=1, keepdims=True) * eta[:, None]  # rows at caps
        q = q * (m / q.sum())
        # rescale may break row caps; project
        row = q.sum(axis=1)
        over = row > eta
        q[over] *= (eta[over] / row[over])[:, None]
        if not np.isclose(q.sum(), m, rtol=0.05):
            continue  # only compare genuinely feasible competitors
        assert f_star <= obj(q) * (1 + 1e-5)


def test_capped_respects_tight_client():
    """A client with a tiny cap cannot dominate even with huge utility."""
    U = jnp.asarray([[100.0, 100.0], [1.0, 1.0], [1.0, 1.0], [1.0, 1.0]])
    eta = jnp.asarray([0.1, 1.0, 1.0, 1.0])
    p = np.asarray(sampling.solve_waterfilling_capped(U, 1.5, eta))
    assert p[0].sum() <= 0.1 + 1e-6
    np.testing.assert_allclose(p.sum(), 1.5, rtol=1e-4)
