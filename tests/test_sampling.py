"""Property tests for the water-filling sampler (Thm 2/8/9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.core import sampling

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_utilities(rng, V, S, sparsity=0.2):
    U = np.abs(rng.normal(size=(V, S))) + 1e-3
    mask = rng.uniform(size=(V, S)) > sparsity
    # every processor keeps at least one available model
    mask[np.arange(V), rng.integers(0, S, V)] = True
    return U * mask


@given(st.integers(2, 30), st.integers(1, 5), st.floats(0.5, 0.95),
       st.integers(0, 10_000))
def test_waterfilling_feasibility(V, S, m_frac, seed):
    rng = np.random.default_rng(seed)
    U = _rand_utilities(rng, V, S)
    m = max(1.0, m_frac * V)
    p = np.asarray(sampling.solve_waterfilling(jnp.asarray(U), m))
    assert np.all(p >= -1e-9)
    assert np.all(p.sum(axis=1) <= 1.0 + 1e-5)
    # budget met exactly (m < V here)
    if m < V - 1:
        np.testing.assert_allclose(p.sum(), m, rtol=1e-4)
    # unavailable (zero-utility) pairs never sampled
    assert np.all(p[U == 0] == 0)


@given(st.integers(3, 16), st.integers(1, 4), st.integers(0, 10_000))
def test_waterfilling_optimality(V, S, seed):
    """The closed form must beat random feasible distributions on the
    objective sum ||U||^2/p (it is the argmin)."""
    rng = np.random.default_rng(seed)
    U = _rand_utilities(rng, V, S, sparsity=0.0)
    m = 0.5 * V
    p_star = np.asarray(sampling.solve_waterfilling(jnp.asarray(U), m))

    def objective(p):
        with np.errstate(divide="ignore"):
            val = np.where(U > 0, U ** 2 / np.maximum(p, 1e-30), 0.0)
        return val.sum()

    f_star = objective(p_star)
    for _ in range(20):
        q = rng.uniform(0.01, 1.0, size=(V, S))
        q = q / q.sum(axis=1, keepdims=True)          # rows sum to 1
        q = q * (m / V)                               # total = m, rows <= 1
        assert f_star <= objective(q) * (1 + 1e-6)


def test_waterfilling_full_participation():
    U = jnp.asarray(np.abs(np.random.default_rng(0).normal(size=(6, 2))) + 0.1)
    p = np.asarray(sampling.solve_waterfilling(U, 6.0))
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-5)


def test_waterfilling_matches_paper_structure():
    """Saturated set = largest-M processors; scaled set shares the rest."""
    U = jnp.asarray([[10.0, 10.0], [0.1, 0.1], [0.1, 0.1], [0.1, 0.1]])
    m = 1.5
    p = np.asarray(sampling.solve_waterfilling(U, m))
    # processor 0 has overwhelming utility -> saturated (sum_s p = 1)
    np.testing.assert_allclose(p[0].sum(), 1.0, rtol=1e-4)
    np.testing.assert_allclose(p.sum(), m, rtol=1e-4)


def test_assignment_unbiased():
    """E[1_{(v,s)}] == p_{s|v} and E[||H||_1] == 1 (Eq. 16)."""
    rng = np.random.default_rng(0)
    N, S = 12, 3
    d = rng.dirichlet(np.ones(N), size=S).T                  # [N,S]
    B = np.ones(N)
    avail = np.ones((N, S), bool)
    losses = jnp.asarray(np.abs(rng.normal(size=(N, S))) + 0.5)
    p = sampling.lvr_probabilities(losses, jnp.asarray(d), jnp.asarray(B),
                                   jnp.asarray(avail), m=4.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 3000)
    acts = jax.vmap(lambda k: sampling.sample_assignment(k, p))(keys)
    emp = np.asarray(acts.mean(axis=0))
    np.testing.assert_allclose(emp, np.asarray(p), atol=0.03)
    # global step size: E[sum_active d/(B p)] = 1 per model
    coeff = np.where(np.asarray(p) > 0, d / np.maximum(np.asarray(p), 1e-30), 0.0)
    H1 = (np.asarray(acts) * coeff[None]).sum(axis=1)        # [draws, S]
    np.testing.assert_allclose(H1.mean(axis=0), 1.0, atol=0.06)


def test_random_probabilities_budget():
    rng = np.random.default_rng(1)
    N, S = 10, 4
    d = rng.dirichlet(np.ones(N), size=S).T
    B = rng.integers(1, 4, N).astype(float)
    avail = rng.uniform(size=(N, S)) > 0.1
    avail[:, 0] = True
    m = 6.0
    p = np.asarray(sampling.random_probabilities(
        jnp.asarray(d), jnp.asarray(B), jnp.asarray(avail), m))
    assert np.all(p.sum(axis=1) <= 1 + 1e-5)
    assert p.sum() <= m + 1e-4


def test_roundrobin_mask_cycles():
    avail = jnp.ones((5, 3))
    for r in range(6):
        mask = np.asarray(sampling.roundrobin_mask(avail, r))
        assert mask[:, r % 3].all()
        assert mask.sum() == 5
