"""Partitioner property tests (paper §6.1 statistics)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.data import partition, synthetic

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def test_label_shard_statistics():
    rng = np.random.default_rng(0)
    x, y = synthetic.make_image_task(rng, n_classes=10, n_per_class=400)
    part = partition.label_shard_partition(rng, x, y, n_clients=120)
    counts = part["count"]
    high = part["high"]
    assert high.sum() == 12                        # 10% of 120
    # high-data clients hold ~52.6% of the data (paper: 52.6%)
    frac = counts[high].sum() / counts.sum()
    assert 0.40 < frac < 0.65, frac
    # each client sees ~30% of labels
    for i in rng.choice(120, 10, replace=False):
        labels = np.unique(part["y"][i])
        assert len(labels) <= 4                    # 30% of 10 rounded up + pad

    # wrap-padding: every padded row equals a real row
    i = int(np.argmin(counts))
    c = counts[i]
    real = part["x"][i][:c]
    for j in range(c, part["x"].shape[1]):
        assert any(np.array_equal(part["x"][i][j], real[k % c])
                   for k in range(c)) or np.array_equal(
                       part["x"][i][j], part["x"][i][j % c])


@given(st.integers(2, 5), st.integers(0, 1000))
def test_budgets_distribution(S, seed):
    rng = np.random.default_rng(seed)
    avail = partition.availability(rng, 120, S)
    B = partition.processor_budgets(rng, avail)
    si = avail.sum(axis=1)
    assert np.all(B >= 1)
    assert np.all(B <= np.maximum(si, 1))
    # 25% have B = |S_i|
    n_full = (B == si).sum()
    assert n_full >= 120 // 4                      # ceil group sizes overlap


@given(st.integers(2, 5), st.integers(0, 500))
def test_availability(S, seed):
    rng = np.random.default_rng(seed)
    avail = partition.availability(rng, 100, S)
    assert avail.shape == (100, S)
    per_client = avail.sum(axis=1)
    assert np.all(per_client >= S - 1)
    assert (per_client == S).sum() == 90           # 90% can train all


def test_stream_partition_non_iid():
    rng = np.random.default_rng(1)
    x, y, sid = synthetic.make_char_task(rng, vocab=32, n_streams=40,
                                         stream_len=128, seq_len=16)
    part = partition.stream_partition(rng, x, y, sid, n_clients=20)
    assert part["x"].shape[0] == 20
    assert np.all(part["count"] > 0)


def test_image_task_separable():
    """The synthetic classes must be learnable (sanity for accuracy claims)."""
    rng = np.random.default_rng(2)
    x, y = synthetic.make_image_task(rng, n_classes=4, n_per_class=100)
    # nearest-class-mean classifier should beat chance comfortably
    means = np.stack([x[y == c].mean(axis=0) for c in range(4)])
    d = ((x[:, None] - means[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (d.argmin(axis=1) == y).mean()
    assert acc > 0.7, acc
