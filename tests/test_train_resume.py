"""End-to-end distributed trainer test: ``--method stalevre`` with the
stale store carried in the shared ``ExperimentState`` pytree, killed after
a checkpoint and resumed with ``--resume`` — the continued metrics must be
IDENTICAL to an uninterrupted run (every random draw is derived from the
checkpointed key)."""
import pytest

pytestmark = pytest.mark.slow   # transformer compiles: minutes-tier

BASE = ["--arch", "qwen3-0.6b-reduced", "--models", "2", "--rounds", "4",
        "--clients", "10", "--per-client", "8", "--local-batch", "2",
        "--local-steps", "1", "--seq-len", "32", "--method", "stalevre",
        "--log-every", "100"]


def _run(extra):
    from repro.launch.train import build_parser, train
    args = build_parser().parse_args(BASE + extra)
    return train(args)


def test_stalevre_kill_resume_identical(tmp_path):
    full_dir, part_dir = str(tmp_path / "full"), str(tmp_path / "part")
    full = _run(["--out", full_dir])["history"]
    assert len(full) == 4
    # interrupted run: stop at round 2 (checkpointed), then resume
    _run(["--rounds", "2", "--ckpt-every", "2", "--out", part_dir])
    resumed = _run(["--ckpt-every", "2", "--resume",
                    "--out", part_dir])["history"]
    assert len(resumed) == 4
    for a, b in zip(full, resumed):
        for k in a:
            if k == "time_s":
                continue
            assert a[k] == b[k], (k, a[k], b[k])


def test_stale_state_in_checkpoint(tmp_path):
    """The saved state carries the stale store + beta estimator, not just
    params."""
    import numpy as np
    out = str(tmp_path / "ck")
    res = _run(["--rounds", "2", "--ckpt-every", "2", "--out", out])
    st = res["state"]
    assert len(st.method_state) == 2
    ms = st.method_state[0]
    assert "h" in ms and "h_valid" in ms and "beta" in ms
    assert float(np.asarray(ms["h_valid"]).sum()) > 0   # refreshed rows
    import json, os
    man = json.load(open(os.path.join(out, "state_2.json")))
    assert any(".beta_hat" in k for k in man["keys"])
    assert any("h_valid" in k for k in man["keys"])
