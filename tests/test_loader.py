"""Data loader unit tests."""
import numpy as np

from repro.data import partition, synthetic
from repro.data.loader import FederatedDataset, token_shards


def test_cohort_batch_shapes():
    rng = np.random.default_rng(0)
    x, y = synthetic.make_image_task(rng, n_classes=4, n_per_class=50)
    part = partition.label_shard_partition(rng, x, y, n_clients=10)
    ds = FederatedDataset(part)
    assert len(ds) == 10
    batch = ds.cohort_batch(rng, [0, 3, 7], batch=8)
    assert batch["x"].shape == (3, 8, 28, 28, 1)
    assert batch["y"].shape == (3, 8)


def test_sample_batch_respects_count():
    rng = np.random.default_rng(1)
    x, y = synthetic.make_image_task(rng, n_classes=4, n_per_class=50)
    part = partition.label_shard_partition(rng, x, y, n_clients=10)
    ds = FederatedDataset(part)
    c = ds.clients[0]
    for _ in range(5):
        b = c.sample_batch(rng, 16)
        # sampled rows must come from the REAL (non-pad) region
        for row in b["x"]:
            assert any(np.array_equal(row, c.arrays["x"][i])
                       for i in range(c.count))


def test_epoch_batches_cover_without_replacement():
    rng = np.random.default_rng(2)
    data = {"x": np.arange(40).reshape(10, 4),
            "y": np.arange(10)[:, None].repeat(4, 1),
            "count": np.full(10, 4)}
    part = {"x": data["x"][:, :, None], "y": data["y"], "count": data["count"]}
    ds = FederatedDataset(part)
    seen = []
    for b in ds.clients[2].epoch_batches(rng, 2):
        seen.extend(b["x"][:, 0].tolist())
    assert sorted(seen) == sorted(data["x"][2].tolist())


def test_token_shards():
    rng = np.random.default_rng(3)
    data = rng.integers(0, 100, (6, 5, 9)).astype(np.int32)
    ds = token_shards(data)
    assert len(ds) == 6
    b = ds.cohort_batch(rng, [1, 2], 3)
    assert b["x"].shape == (2, 3, 8)
    np.testing.assert_array_equal(b["x"][:, :, 1:], b["y"][:, :, :-1])
